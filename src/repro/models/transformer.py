"""Pattern-scanned transformer stack shared by all 10 assigned archs.

A model's layer sequence is ``cfg.pattern`` — e.g. gemma3's
``(L,L,L,L,L,G)`` × 10 + ``(L,L)``, recurrentgemma's ``(R,R,L)`` × 12 +
``(R,R)``, mamba2's ``(S,)`` × 24.  The stack splits the pattern into a
repeated *unit* and a remainder:

* the ``n_reps`` repetitions of the unit are **scanned** with stacked
  params (MaxText-style) — HLO size and compile time stay O(unit), not
  O(n_layers), which is what makes the 512-device dry-run of an 80-layer
  model compile in seconds;
* the remainder layers are unrolled.

Layer kinds: ``G`` global attn, ``L`` sliding-window attn, ``E``
bidirectional (encoder) attn, ``R`` RG-LRU recurrent, ``S`` Mamba-2 SSD.
Attention kinds pair with an MLP (dense SwiGLU or MoE per cfg); ``R``
pairs with a dense MLP; ``S`` is a bare SSD block (Mamba topology).

Caches are pytrees mirroring the param structure: a stacked stage cache
(leading ``n_reps`` axis, consumed/emitted as scan xs/ys) plus a list for
the remainder.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import sharding
from repro.models.mamba2 import ssd_block, ssd_cache_init, ssd_init
from repro.models.moe import moe_ffn, moe_init
from repro.models.rglru import rglru_block, rglru_cache_init, rglru_init


# ---------------------------------------------------------------- pattern

def split_pattern(cfg, n_layers: Optional[int] = None,
                  unit: Optional[Sequence[str]] = None):
    """-> (unit, n_reps, remainder_kinds)."""
    if unit is None:
        unit = (cfg.layer_pattern if cfg.layer_pattern is not None
                else ({"ssm": ("S",)}.get(cfg.family, ("G",))))
    unit = tuple(unit)
    n = cfg.n_layers if n_layers is None else n_layers
    n_reps = n // len(unit)
    rem = (unit * (n_reps + 1))[n_reps * len(unit): n]
    return unit, n_reps, rem


def attn_spec(cfg, kind: str, *, cross: bool = False) -> L.AttnSpec:
    theta = cfg.rope_theta
    window = 0
    if kind == "L":
        window = cfg.local_window
        theta = cfg.rope_theta_local or cfg.rope_theta
    return L.AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim_, causal=(kind != "E") and not cross,
        window=window, qk_norm=cfg.qk_norm, qkv_bias=cfg.qkv_bias,
        rope_theta=theta, use_rope=not cross)


# ------------------------------------------------------------------ block

def block_init(key, cfg, kind: str, *, cross: bool = False,
               dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p = {"ln1": jnp.zeros((D,), dtype)}
    if kind in ("G", "L", "E"):
        p["attn"] = L.attn_init(ks[0], D, attn_spec(cfg, kind), dtype=dtype)
        if cross:
            p["ln_x"] = jnp.zeros((D,), dtype)
            p["xattn"] = L.attn_init(
                ks[1], D, attn_spec(cfg, kind, cross=True),
                kv_d_model=D, dtype=dtype)
        p["ln2"] = jnp.zeros((D,), dtype)
        if cfg.n_experts:
            p["moe"] = moe_init(ks[2], D, cfg.n_experts,
                                cfg.expert_d_ff or cfg.d_ff,
                                cfg.n_shared_experts, dtype=dtype)
        else:
            p["mlp"] = L.mlp_init(ks[2], D, cfg.d_ff, dtype)
    elif kind == "R":
        p["lru"] = rglru_init(ks[0], cfg, dtype)
        p["ln2"] = jnp.zeros((D,), dtype)
        p["mlp"] = L.mlp_init(ks[2], D, cfg.d_ff, dtype)
    elif kind == "S":
        p["ssd"] = ssd_init(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def block_cache_init(cfg, kind: str, batch: int, cache_len: int,
                     dtype=jnp.bfloat16) -> Optional[dict]:
    """Decode cache for one block (None for encoder kinds)."""
    if kind in ("G", "L"):
        Sc = min(cache_len, cfg.local_window) if kind == "L" else cache_len
        K, hd = cfg.n_kv_heads, cfg.head_dim_
        c = {
            "k": jnp.zeros((batch, Sc, K, hd), dtype),
            "v": jnp.zeros((batch, Sc, K, hd), dtype),
            "pos": jnp.full((Sc,), -(2 ** 30), jnp.int32),
        }
        if dtype == jnp.int8:          # quantized KV cache (+ fp scales)
            c["k_scale"] = jnp.zeros((batch, Sc, K), jnp.float32)
            c["v_scale"] = jnp.zeros((batch, Sc, K), jnp.float32)
        return {"attn": c}
    if kind == "R":
        rdt = jnp.bfloat16 if dtype == jnp.int8 else dtype
        return {"lru": rglru_cache_init(cfg, batch, rdt)}
    if kind == "S":
        rdt = jnp.bfloat16 if dtype == jnp.int8 else dtype
        return {"ssd": ssd_cache_init(cfg, batch, rdt)}
    return None


def block_apply(params: dict, x: jax.Array, cfg, kind: str,
                positions: jax.Array, *,
                enc: Optional[jax.Array] = None,
                enc_pos: Optional[jax.Array] = None,
                static_kv: Optional[tuple] = None,
                cache: Optional[dict] = None,
                collect_kv: bool = False):
    """-> (x, aux_loss, new_cache_or_kv_or_None).

    collect_kv (prefill): instead of consuming a cache, return the block's
    freshly projected (k, v) so the caller can build the decode cache.
    """
    aux = jnp.zeros((), jnp.float32)
    out_state = None
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind in ("G", "L", "E"):
        spec = attn_spec(cfg, kind)
        attn_cache = cache.get("attn") if cache else None
        out, st = L.attention(params["attn"], spec, h, positions,
                              cache=attn_cache, return_kv=collect_kv,
                              norm_eps=cfg.norm_eps)
        x = x + out
        if st is not None:
            out_state = {"attn": st}
        if "xattn" in params:
            hx = L.rms_norm(x, params["ln_x"], cfg.norm_eps)
            xspec = attn_spec(cfg, kind, cross=True)
            out, _ = L.attention(params["xattn"], xspec, hx, positions,
                                 kv_x=enc, kv_positions=enc_pos,
                                 static_kv=static_kv, norm_eps=cfg.norm_eps)
            x = x + out
        h2 = L.rms_norm(x, params["ln2"], cfg.norm_eps)
        if "moe" in params:
            out, a = moe_ffn(params["moe"], h2, top_k=cfg.top_k,
                             capacity_factor=cfg.moe_capacity_factor,
                             tokens_per_group=cfg.moe_tokens_per_group,
                             impl=cfg.moe_impl)
            aux = aux + a
        else:
            out = L.mlp(params["mlp"], h2)
        x = x + out
    elif kind == "R":
        out, st = rglru_block(params["lru"], h, cfg,
                              cache=cache.get("lru") if cache else None,
                              collect_state=collect_kv)
        x = x + out
        if st is not None:
            out_state = {"lru": st}
        h2 = L.rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + L.mlp(params["mlp"], h2)
    elif kind == "S":
        out, st = ssd_block(params["ssd"], h, cfg,
                            cache=cache.get("ssd") if cache else None,
                            collect_state=collect_kv)
        x = x + out
        if st is not None:
            out_state = {"ssd": st}
    return x, aux, out_state


# ------------------------------------------------------------------ stack

def stack_init(key, cfg, *, n_layers: Optional[int] = None,
               unit: Optional[Sequence[str]] = None, cross: bool = False,
               dtype=jnp.float32) -> dict:
    unit, n_reps, rem = split_pattern(cfg, n_layers, unit)

    def unit_init(k):
        kk = jax.random.split(k, len(unit))
        return tuple(block_init(kk[i], cfg, kind, cross=cross, dtype=dtype)
                     for i, kind in enumerate(unit))

    keys = jax.random.split(key, max(n_reps, 1) + len(rem))
    stages = jax.vmap(unit_init)(keys[:n_reps]) if n_reps else None
    rem_p = tuple(block_init(keys[n_reps + i], cfg, kind, cross=cross,
                             dtype=dtype)
                  for i, kind in enumerate(rem))
    return {"stages": stages, "rem": rem_p}


def stack_cache_init(cfg, batch: int, cache_len: int, *,
                     n_layers: Optional[int] = None,
                     unit: Optional[Sequence[str]] = None,
                     dtype=jnp.bfloat16) -> dict:
    unit, n_reps, rem = split_pattern(cfg, n_layers, unit)
    unit_cache = tuple(block_cache_init(cfg, k, batch, cache_len, dtype)
                       for k in unit)
    stages = (jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_reps,) + a.shape).copy(), unit_cache)
        if n_reps else None)
    rem_c = tuple(block_cache_init(cfg, k, batch, cache_len, dtype)
                  for k in rem)
    return {"stages": stages, "rem": rem_c}


def _stack_xs(params, cross_kv, cache, mode: str):
    """Pack scan xs; static layout tag keeps the body unpackable."""
    xs = [params["stages"]]
    if cross_kv is not None:
        xs.append(cross_kv["stages"])
    if mode == "decode":
        xs.append(cache["stages"])
    return tuple(xs)


def stack_apply(params: dict, x: jax.Array, cfg, positions: jax.Array, *,
                n_layers: Optional[int] = None,
                unit: Optional[Sequence[str]] = None,
                enc: Optional[jax.Array] = None,
                enc_pos: Optional[jax.Array] = None,
                cross_kv: Optional[dict] = None,
                cache: Optional[dict] = None,
                mode: str = "train"):
    """Run the full stack.

    mode: "train" (no cache I/O) | "prefill" (emit kv/state for cache
    build) | "decode" (consume + emit caches).
    Returns (x, aux, states) where states mirrors {"stages", "rem"} and is
    None in train mode.
    """
    unit, n_reps, rem = split_pattern(cfg, n_layers, unit)
    has_cross = cross_kv is not None
    aux0 = jnp.zeros((), jnp.float32)

    def apply_unit(carry, xs):
        x, aux = carry
        i = 0
        blocks = xs[i]; i += 1
        ckv = xs[i] if has_cross else None
        i += has_cross
        cch = xs[i] if mode == "decode" else None
        # ZeRO-3: gather this unit's FSDP-sharded weights just-in-time
        # (inside the scan body, so one unit's weights live at a time)
        blocks = sharding.gather_for_use(blocks)
        states = []
        for li, kind in enumerate(unit):
            x, a, st = block_apply(
                blocks[li], x, cfg, kind, positions,
                enc=enc, enc_pos=enc_pos,
                static_kv=(ckv[li] if ckv is not None else None),
                cache=(cch[li] if cch is not None else None),
                collect_kv=(mode == "prefill"))
            aux = aux + a
            states.append(st)
        ys = tuple(states) if mode != "train" else None
        return (x, aux), ys

    body = apply_unit
    if mode == "train" and cfg.remat:
        body = jax.checkpoint(apply_unit)

    stage_states = None
    aux = aux0
    if n_reps:
        (x, aux), stage_states = lax.scan(
            body, (x, aux0), _stack_xs(params, cross_kv, cache, mode))

    rem_states = []
    for i, kind in enumerate(rem):
        x, a, st = block_apply(
            sharding.gather_for_use(params["rem"][i]), x, cfg, kind,
            positions,
            enc=enc, enc_pos=enc_pos,
            static_kv=(cross_kv["rem"][i] if has_cross else None),
            cache=(cache["rem"][i] if mode == "decode" else None),
            collect_kv=(mode == "prefill"))
        aux = aux + a
        rem_states.append(st)

    states = None
    if mode != "train":
        states = {"stages": stage_states, "rem": tuple(rem_states)}
    return x, aux, states


def stack_cross_kv(params: dict, cfg, enc: jax.Array, *,
                   n_layers: Optional[int] = None,
                   unit: Optional[Sequence[str]] = None) -> dict:
    """Pre-project every decoder layer's cross-attention (k, v) from the
    encoder output (cached once at prefill; decode then never re-projects
    the encoder states)."""
    unit, n_reps, rem = split_pattern(cfg, n_layers, unit)
    xspec = attn_spec(cfg, "G", cross=True)

    def unit_kv(blocks):
        return tuple(L.attn_kv(b["xattn"], xspec, enc, cfg.norm_eps)
                     for b in blocks)

    stages = None
    if n_reps:
        stages = jax.vmap(unit_kv, in_axes=(0,))(params["stages"])
    rem_kv = tuple(L.attn_kv(b["xattn"], xspec, enc, cfg.norm_eps)
                   for b in params["rem"])
    return {"stages": stages, "rem": rem_kv}


def states_to_cache(states: dict, cfg, positions: jax.Array,
                    cache_len: int, *, n_layers: Optional[int] = None,
                    unit: Optional[Sequence[str]] = None,
                    dtype=jnp.bfloat16) -> dict:
    """Convert prefill-mode stack states (raw kv / recurrent states) into
    decode caches."""
    unit_, n_reps, rem = split_pattern(cfg, n_layers, unit)
    pos = positions[0] if positions.ndim == 2 else positions

    def convert(kind, st):
        if st is None:
            return None
        if "attn" in st:
            k, v = st["attn"]
            Sc = (min(cache_len, cfg.local_window) if kind == "L"
                  else cache_len)
            return {"attn": L.build_attn_cache(k, v, pos, Sc, dtype)}
        return st    # recurrent states are already the cache

    stages = None
    if n_reps and states["stages"] is not None:
        def unit_convert(sts):
            return tuple(convert(k, sts[i]) for i, k in enumerate(unit_))
        stages = jax.vmap(unit_convert)(states["stages"])
    rem_c = tuple(convert(k, states["rem"][i]) for i, k in enumerate(rem))
    return {"stages": stages, "rem": rem_c}
