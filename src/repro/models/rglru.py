"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Linear recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), gates r_t, i_t = sigmoid(W x).
Train/prefill evaluate it with ``lax.associative_scan`` (log-depth —
the TPU-native answer to the GPU's sequential recurrence); decode is the
single-step update, O(1) state for ``long_500k``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.models.sharding import constrain

_C = 8.0


def rglru_init(key, cfg, dtype=jnp.float32) -> dict:
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 6)
    # Lambda init so a^c spans ~U(0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[4], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^-1(-log u / c)
    return {
        "wx": dense_init(ks[0], (D, W), 0, dtype),
        "wy": dense_init(ks[1], (D, W), 0, dtype),       # gate branch
        "conv": dense_init(ks[2], (cfg.conv_width, W), 0, dtype),
        "w_r": dense_init(ks[3], (W, W), 0, dtype),
        "w_i": dense_init(ks[5], (W, W), 0, dtype),
        "b_r": jnp.zeros((W,), jnp.float32),
        "b_i": jnp.zeros((W,), jnp.float32),
        "lambda": lam,
        "out": dense_init(jax.random.fold_in(key, 7), (W, D), 0, dtype),
    }


def _lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None):
    """h_t = a_t * h_{t-1} + b_t over axis 1 via associative scan.
    a, b: (B, S, W). Returns (h (B, S, W), h_last (B, W))."""
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
        # (a_0 multiplies h0, already applied; zero it so scan is closed)
        a = a.at[:, 0].set(jnp.zeros_like(a[:, 0]))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    ah, bh = lax.associative_scan(combine, (a, b), axis=1)
    return bh, bh[:, -1]


def rglru_block(params: dict, x: jax.Array, cfg, *,
                cache: dict | None = None, collect_state: bool = False):
    """x: (B, S, D). cache: {"conv": (B, W-1, lru_w), "state": (B, lru_w)}.
    collect_state (prefill): run cache-free but return the final
    recurrent + conv state as a fresh decode cache.
    Returns (out (B, S, D), new_cache_or_None)."""
    Wd = cfg.lru_width or cfg.d_model
    Cw = cfg.conv_width

    xb = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    gate = jnp.einsum("bsd,dw->bsw", x, params["wy"])
    xb = constrain(xb, ("pod", "data"), None, "model")

    new_cache = None
    if cache is None:
        pad = jnp.pad(xb, ((0, 0), (Cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([cache["conv"].astype(xb.dtype), xb], axis=1)
    conv = sum(pad[:, i:i + xb.shape[1]] * params["conv"][i]
               for i in range(Cw))
    if cache is not None:
        new_conv = pad[:, -(Cw - 1):]

    # §Perf (recurrentgemma train iter 4): the r/i gate matmuls contract
    # the model-sharded W dim — left alone each emits a (B, S, W) psum
    # (2 x ~2 GB f32 all-reduce per R layer). Gathering the SHARED gate
    # input once in bf16 (its information content is bf16 — conv runs in
    # bf16) and keeping w_r/w_i output-sharded turns 2 psums into 1
    # all-gather at 1/8 the bytes; the f32 upcast happens locally.
    conv = constrain(conv, ("pod", "data"), None, None)
    cf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", cf, params["w_r"]
                                  .astype(jnp.float32)) + params["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", cf, params["w_i"]
                                  .astype(jnp.float32)) + params["b_i"])
    r = constrain(r, ("pod", "data"), None, "model")
    i = constrain(i, ("pod", "data"), None, "model")
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * cf)

    if cache is None:
        h, h_last = _lru_scan(a, gated, None)
        if collect_state:
            new_cache = {"conv": pad[:, -(Cw - 1):], "state": h_last}
    else:
        h0 = cache["state"]
        h_last = a[:, 0] * h0 + gated[:, 0]
        h = h_last[:, None]
        if xb.shape[1] > 1:                     # multi-token with state
            h, h_last = _lru_scan(a, gated, h0)
        new_cache = {"conv": new_conv, "state": h[:, -1]}

    out = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", out, params["out"])
    return constrain(out, ("pod", "data"), None, None), new_cache


def rglru_cache_init(cfg, batch: int, dtype=jnp.float32) -> dict:
    Wd = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, Wd), dtype),
        "state": jnp.zeros((batch, Wd), jnp.float32),
    }
