"""repro — multi-pod JAX framework around subsequence-DTW (sDTW).

Reproduction + scale-out of "Optimizing sDTW for AMD GPUs" (CS.DC 2024),
adapted to TPU per DESIGN.md.

One front door::

    import repro
    res = repro.sdtw(queries, reference,
                     outputs=("cost", "start", "end"))   # SDTWResult
    aligner = repro.Aligner(reference, band=128)         # session form
    res = aligner(queries)                               # warm: dispatch

Exports are lazy so ``import repro`` stays free of JAX/Pallas imports
until an entry point is actually touched.
"""

__version__ = "1.1.0"

__all__ = ["sdtw", "Aligner", "SDTWResult",
           "DPSpec", "ALL_OUTPUTS", "tune", "dp"]

_LAZY = {
    "sdtw": ("repro.core.api", "sdtw"),
    "Aligner": ("repro.core.session", "Aligner"),
    "SDTWResult": ("repro.core.result", "SDTWResult"),
    "ALL_OUTPUTS": ("repro.core.result", "ALL_OUTPUTS"),
    "DPSpec": ("repro.core.spec", "DPSpec"),
    "tune": ("repro.tune", None),    # the autotuner subpackage itself
    "dp": ("repro.dp", None),        # the recurrence-algebra subpackage
}


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    mod = importlib.import_module(module)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value          # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
