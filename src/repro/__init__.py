"""repro — multi-pod JAX framework around subsequence-DTW (sDTW).

Reproduction + scale-out of "Optimizing sDTW for AMD GPUs" (CS.DC 2024),
adapted to TPU per DESIGN.md.
"""

__version__ = "1.0.0"
